"""Quickstart: the GraphX data model and operators in five minutes.

  PYTHONPATH=src python examples/quickstart.py

Builds a small property graph, tours the narrow-waist operators (Listing 4
of the paper), and runs PageRank + connected components + triangle count.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import Graph, Col, algorithms as alg
from repro.data import rmat, symmetrize


def main():
    # --- a social-network-shaped graph (power-law, 1k vertices) -----------
    gd = rmat(10, 8, seed=42)
    print(f"graph: {gd.num_vertices} vertices, {gd.num_edges} edges")

    vids = np.arange(gd.num_vertices, dtype=np.int64)
    g = Graph.from_edges(
        gd.src, gd.dst,
        vertex_keys=vids,
        vertex_values={"age": (20 + vids % 50).astype(np.float32)},
        default_vertex={"age": np.float32(0)},
        num_partitions=4)

    # --- collection view + data-parallel ops (Listing 3) -------------------
    vertices = g.vertices()
    n_over_40 = vertices.filter(lambda k, v: v["age"] > 40).count()
    print(f"vertices over 40: {int(n_over_40)}")

    # --- triplets + mrTriplets (Fig. 2 of the paper: senior neighbours) ----
    def more_senior(sv, ev, dv):
        return {"n": jnp.where(sv["age"] > dv["age"], 1.0, 0.0)}

    seniors, exists, _, metrics = g.mrTriplets(more_senior, "sum")
    print(f"mrTriplets join arity after elimination: {metrics['join_arity']} "
          f"(UDF reads both endpoints -> 3-way)")

    # --- subgraph: restrict to the under-40 community ----------------------
    young = g.subgraph(vpred=lambda vid, v: v["age"] <= 40)
    print(f"subgraph shares structure with parent: {young.s is g.s}")

    # --- graph algorithms from the algorithm library -----------------------
    pr = alg.pagerank(g, num_iters=15)
    ids, vals = pr.graph.vertices_to_numpy()
    top = ids[np.argsort(-vals['pr'])[:5]]
    print(f"top-5 by PageRank: {top.tolist()}")

    sgd = symmetrize(gd)
    sg = Graph.from_edges(sgd.src, sgd.dst, num_partitions=4)
    cc = alg.connected_components(sg)
    _, ccv = cc.graph.vertices_to_numpy()
    print(f"connected components: {len(set(ccv['cc'].tolist()))} "
          f"(in {cc.supersteps} supersteps)")

    _, tri, _ = alg.triangle_count(sg, n_ids=gd.num_vertices)
    print(f"triangles: {int(round(float(tri)))}")


if __name__ == "__main__":
    main()
