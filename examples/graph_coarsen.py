"""Graph coarsening (paper Listing 7): build a DOMAIN graph from a page
graph — the pipeline that pure graph-parallel systems cannot express.

  PYTHONPATH=src python examples/graph_coarsen.py

Pages live in domains (vid // 16 here); we contract all intra-domain links
(subgraph -> connected components -> reduceByKey -> rebuild) and then rank
the resulting domain graph — data-parallel and graph-parallel operators
composed in one program.
"""
import numpy as np

from repro.core import Graph, algorithms as alg
from repro.data import rmat, symmetrize


def main():
    gd = symmetrize(rmat(9, 6, seed=7))
    vids = np.arange(gd.num_vertices, dtype=np.int64)
    domains = (vids // 16).astype(np.int32)

    g = Graph.from_edges(
        gd.src, gd.dst, vertex_keys=vids,
        vertex_values={"pages": np.ones(gd.num_vertices, np.float32),
                       "dom": domains},
        default_vertex={"pages": np.float32(0), "dom": np.int32(-1)},
        num_partitions=4)
    print(f"page graph: {g.s.num_vertices} pages, {g.s.num_edges} links")

    coarse = alg.coarsen(
        g, epred=lambda sv, ev, dv: sv["dom"] == dv["dom"], merge="sum")
    print(f"domain graph: {coarse.s.num_vertices} super-vertices, "
          f"{coarse.s.num_edges} inter-domain links")

    cvids, cvals = coarse.vertices_to_numpy()
    print(f"total pages preserved: {int(cvals['pages'].sum())} "
          f"== {gd.num_vertices}")

    res = alg.pagerank(coarse, num_iters=10)
    dv, dvals = res.graph.vertices_to_numpy()
    top = np.argsort(-dvals["pr"])[:5]
    print("top domains by PageRank:")
    for i in top:
        print(f"  domain(super-vertex {int(dv[i])}): "
              f"pr={dvals['pr'][i]:.3f} pages={int(dvals['pages'][i])}")


if __name__ == "__main__":
    main()
